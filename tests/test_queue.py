"""Job lifecycle queue tests: states, ordering, timed release, EASY
backfill over the pruning aggregates, and grow escalation."""
import pytest

from repro.core import (JobQueue, JobState, Jobspec, SchedulerInstance,
                        SimClock, SimulatedEC2Provider, WallClock,
                        build_chain, build_cluster)


def _queue(nodes=2, backfill=True, allow_grow=False, external=False):
    g = build_cluster(nodes=nodes)
    prov = SimulatedEC2Provider(seed=1) if external else None
    sched = SchedulerInstance("q", g, external=prov)
    return JobQueue(sched, clock=SimClock(), backfill=backfill,
                    allow_grow=allow_grow)


NODE = Jobspec.hpc(nodes=1, sockets=2, cores=32)


def test_job_states_and_timed_release():
    q = _queue(nodes=1)
    job = q.submit(NODE, walltime=10.0)
    assert job.state is JobState.PENDING
    q.step()
    assert job.state is JobState.RUNNING
    assert job.start_time == 0.0 and job.end_time == 10.0
    # resources held while running
    g = q.scheduler.graph
    assert g.vertex(g.roots[0]).agg_free.get("node", 0) == 0
    q.advance(10.0)
    assert job.state is JobState.COMPLETED
    # timed release freed everything (set_free through release)
    assert g.vertex(g.roots[0]).agg_free["node"] == 1
    assert g.validate_tree()


def test_fcfs_within_priority_and_priority_wins():
    q = _queue(nodes=1, backfill=False)
    a = q.submit(NODE, walltime=5.0, priority=0)
    q.step()
    assert a.state is JobState.RUNNING
    b = q.submit(NODE, walltime=5.0, priority=0)
    c = q.submit(NODE, walltime=5.0, priority=7)
    # after a ends, priority beats FCFS: c runs before the earlier b
    q.advance(5.0)
    assert c.state is JobState.RUNNING and b.state is JobState.PENDING
    q.advance(5.0)
    assert b.state is JobState.RUNNING
    q.advance(5.0)
    assert all(j.state is JobState.COMPLETED for j in (a, b, c))


def test_queue_drain_completes_everything():
    q = _queue(nodes=2)
    jobs = [q.submit(NODE, walltime=float(5 + i)) for i in range(6)]
    done = q.drain()
    assert len(done) == 6
    assert all(j.state is JobState.COMPLETED for j in jobs)
    assert q.scheduler.graph.validate_tree()
    s = q.stats()
    assert s.completed == 6 and s.pending == 0
    assert s.utilization > 0


def test_easy_backfill_does_not_delay_head():
    """Small jobs jump a blocked wide job only if they end before the
    head's shadow time; an over-long candidate must wait."""
    q = _queue(nodes=2)
    hog = q.submit(NODE, walltime=100.0)
    q.step()
    wide = q.submit(Jobspec.hpc(nodes=2, sockets=4, cores=64),
                    walltime=10.0, priority=5)
    short = q.submit(Jobspec.hpc(nodes=0, sockets=1, cores=8),
                     walltime=20.0)
    long_ = q.submit(Jobspec.hpc(nodes=0, sockets=1, cores=8),
                     walltime=500.0)
    q.step()
    assert wide.state is JobState.PENDING
    assert short.state is JobState.RUNNING      # fits + ends by t=100
    assert long_.state is JobState.PENDING      # would delay the head
    q.advance(100.0)
    assert wide.state is JobState.RUNNING
    assert wide.start_time == 100.0             # exactly the reservation
    q.drain()
    assert long_.state is JobState.COMPLETED


def test_backfill_disabled_is_strict_fifo():
    q = _queue(nodes=2, backfill=False)
    q.submit(NODE, walltime=100.0)
    q.step()
    q.submit(Jobspec.hpc(nodes=2, sockets=4, cores=64), walltime=10.0)
    short = q.submit(Jobspec.hpc(nodes=0, sockets=1, cores=8),
                     walltime=1.0)
    q.step()
    assert short.state is JobState.PENDING


def test_cancel_pending_and_running():
    q = _queue(nodes=1)
    a = q.submit(NODE, walltime=50.0)
    b = q.submit(NODE, walltime=50.0)
    q.step()
    assert q.cancel(b.jobid) and b.state is JobState.CANCELLED
    assert q.cancel(a.jobid) and a.state is JobState.CANCELLED
    g = q.scheduler.graph
    assert g.vertex(g.roots[0]).agg_free["node"] == 1
    assert not q.cancel(a.jobid)                # already finished


def test_grow_escalation_through_hierarchy():
    """allow_grow: a job too big for the leaf pulls resources down the
    chain, and its timed release pushes them back up (match_shrink)."""
    h = build_chain([build_cluster(nodes=4), build_cluster(nodes=1)],
                    socket_levels=[1])
    try:
        leaf = h.leaf
        clock = SimClock()
        q = JobQueue(leaf, clock=clock, allow_grow=True)
        local = q.submit(NODE, walltime=5.0)
        big = q.submit(Jobspec.hpc(nodes=2, sockets=4, cores=64),
                       walltime=5.0)
        q.step()
        assert local.state is JobState.RUNNING and local.via == "local"
        assert big.state is JobState.RUNNING and big.via == "parent"
        assert len(leaf.graph.by_type("node")) == 3   # 1 local + 2 grown
        q.advance(5.0)
        assert big.state is JobState.COMPLETED
        # spliced-in vertices removed at the leaf, freed at the parent
        assert len(leaf.graph.by_type("node")) == 1
        freed = [p for p in big.paths if p in h.top.graph]
        assert freed and all(not h.top.graph.vertex(p).allocations
                             for p in freed)
        assert leaf.graph.validate_tree() and h.top.graph.validate_tree()
    finally:
        h.close()


def test_external_burst_rides_the_queue():
    q = _queue(nodes=1, allow_grow=True, external=True)
    a = q.submit(NODE, walltime=10.0)
    burst = q.submit(Jobspec.instances("t2.2xlarge", 2), walltime=10.0)
    q.step()
    assert a.via == "local" and burst.via == "external"
    assert q.scheduler.external_paths
    q.advance(10.0)
    # external vertices evaporate on release (E_i = G_i \ G_0)
    assert not q.scheduler.external_paths
    assert q.scheduler.graph.validate_tree()


def test_wait_time_stats():
    q = _queue(nodes=1)
    a = q.submit(NODE, walltime=10.0)
    b = q.submit(NODE, walltime=10.0)
    q.drain()
    assert a.wait_time == 0.0
    assert b.wait_time == 10.0
    s = q.stats()
    assert s.mean_wait == pytest.approx(5.0)
    assert s.max_wait == pytest.approx(10.0)


def test_wallclock_queue_smoke():
    g = build_cluster(nodes=1)
    q = JobQueue(SchedulerInstance("w", g), clock=WallClock())
    job = q.submit(NODE, walltime=0.0)
    q.step()
    q.step()    # 0-walltime job completes on the next observation
    assert job.state is JobState.COMPLETED


def test_allow_grow_false_never_escalates_shared_alloc():
    """The allow_grow gate holds even for jobs sharing an alloc_id:
    no cloud bursting, strictly local MA (regression test)."""
    q = _queue(nodes=1, allow_grow=False, external=True)
    a = q.submit(Jobspec.hpc(nodes=0, sockets=1, cores=16),
                 walltime=10.0, alloc_id="shared")
    b = q.submit(Jobspec.hpc(nodes=0, sockets=1, cores=16),
                 walltime=10.0, alloc_id="shared")
    c = q.submit(Jobspec.hpc(nodes=0, sockets=1, cores=16),
                 walltime=10.0, alloc_id="shared")
    q.step()
    assert a.state is JobState.RUNNING and b.state is JobState.RUNNING
    assert c.state is JobState.PENDING          # 2 sockets: no 3rd, no burst
    assert not q.scheduler.external_paths
    # each job owns only its own slice of the shared allocation
    assert len(a.paths) == 17 and len(b.paths) == 17
    assert not (set(a.paths) & set(b.paths))
    # per-job override: c may escalate explicitly (mutating a pending
    # job from outside the queue API needs a kick)
    c.grow = True
    q.kick()
    q.step()
    assert c.state is JobState.RUNNING and c.via == "external"


def test_dispatch_bypasses_blocked_head():
    q = _queue(nodes=2)
    q.submit(Jobspec.hpc(nodes=10, sockets=20, cores=320), walltime=5.0)
    q.step()
    job = q.dispatch(Jobspec.hpc(nodes=1, sockets=2, cores=32),
                     walltime=5.0)
    assert job.state is JobState.RUNNING


def test_sibling_reclaimed_resources_survive_release():
    """Finishing a job whose resources came from a sibling subtree must
    free them into the instance's pool — not destroy them (regression:
    _finish used to remove vertices that were never spliced in)."""
    from repro.core import TreeSpec, build_tree
    root_g = build_cluster(nodes=2)
    a_g = root_g.extract([p for p in root_g.paths() if "node0" in p])
    b_g = root_g.extract([p for p in root_g.paths() if "node1" in p])
    h = build_tree(TreeSpec(root_g, name="root",
                            children=[TreeSpec(a_g, name="A"),
                                      TreeSpec(b_g, name="B")]))
    try:
        root = h["root"]
        size_before = root.graph.num_vertices
        # root's own pool empty: everything delegated
        root.graph.set_allocated(
            [p for p in root.graph.paths() if "/node" in p], "delegated")
        q = JobQueue(root, clock=SimClock(), allow_grow=True)
        job = q.submit(NODE, walltime=5.0)
        q.step()
        assert job.state is JobState.RUNNING
        assert job.via.startswith("sibling:")
        q.advance(5.0)
        assert job.state is JobState.COMPLETED
        # the reclaimed vertices are still in the cluster, now free
        assert root.graph.num_vertices == size_before
        assert all(not root.graph.vertex(p).allocations for p in job.paths)
        assert root.graph.validate_tree()
    finally:
        h.close()


def test_release_propagates_through_three_levels():
    """Timed release of a grow matched at L0 must travel the whole
    chain bottom-up: L2 removes its spliced copies, L1 removes its
    pass-through copies, L0 frees the matched vertices (regression:
    release used to stop after one hop, leaking L0 capacity)."""
    graphs = [build_cluster(nodes=4, node_prefix="l0n"),
              build_cluster(nodes=2, node_prefix="l1n"),
              build_cluster(nodes=1, node_prefix="l2n")]
    h = build_chain(graphs, socket_levels=[1])
    try:
        top, mid, leaf = h.instances
        # leaf and mid exhausted: the grow must match at the top
        leaf.match_allocate(Jobspec.hpc(nodes=1, sockets=2, cores=32),
                            jobid="hog-leaf")
        mid.match_allocate(Jobspec.hpc(nodes=2, sockets=4, cores=64),
                           jobid="hog-mid")
        q = JobQueue(leaf, clock=SimClock(), allow_grow=True)
        job = q.submit(NODE, walltime=5.0)
        q.step()
        assert job.state is JobState.RUNNING and job.via == "parent"
        assert any(p.startswith("/cluster0/l0n") for p in job.paths)
        q.advance(5.0)
        assert job.state is JobState.COMPLETED
        # L0: matched vertices freed (not leaked as allocated)
        for p in job.paths:
            assert p in top.graph
            assert not top.graph.vertex(p).allocations, p
        # L1 and L2: pass-through copies removed again
        assert all(p not in mid.graph for p in job.paths)
        assert all(p not in leaf.graph for p in job.paths)
        for inst in h.instances:
            assert inst.graph.validate_tree(), inst.name
        # a second identical job can reuse the same L0 capacity
        job2 = q.submit(NODE, walltime=5.0)
        q.step()
        assert job2.state is JobState.RUNNING and job2.via == "parent"
    finally:
        h.close()


def test_cancelled_pending_jobs_do_not_accumulate():
    q = _queue(nodes=1)
    q.submit(NODE, walltime=1.0)
    q.step()
    for i in range(50):   # a reconciler hammering a full cluster
        j = q.submit(NODE, walltime=1.0)
        q.cancel(j.jobid)
    assert q.stats().submitted == 1
    assert len(q.pending) == 0


def test_blocked_head_not_reescalated_without_state_change():
    """An unsatisfiable head must not re-run its hierarchy escalation
    (RPCs + failure timings at every level) on every idle tick."""
    h = build_chain([build_cluster(nodes=1), build_cluster(nodes=1,
                                                          node_prefix="x")])
    try:
        leaf = h.leaf
        q = JobQueue(leaf, clock=SimClock(), allow_grow=True)
        q.submit(Jobspec.hpc(nodes=8, sockets=16, cores=256), walltime=5.0)
        q.step()
        n_after_first = len(leaf.timings) + len(h.top.timings)
        for _ in range(25):
            q.advance(1.0)      # idle ticks: nothing changed
        assert len(leaf.timings) + len(h.top.timings) == n_after_first
        # a state change (new submit / completion) re-arms scheduling
        ok = q.submit(Jobspec.hpc(nodes=1, sockets=2, cores=32),
                      walltime=1.0)
        q.step()
        assert ok.state is JobState.RUNNING
    finally:
        h.close()


def test_completed_jobs_leave_no_empty_allocations():
    q = _queue(nodes=2)
    for _ in range(10):
        q.submit(NODE, walltime=2.0)
    q.drain()
    assert q.scheduler.allocations == {}


def test_shrink_rejects_invalid_count():
    """``count <= 0`` (or no arguments at all) must be rejected before
    the slice is computed: a negative count would slice from the FRONT
    of ``job.paths`` and silently release most of the allocation — and
    this surface is remotely reachable via the RPC ``shrink`` verb."""
    q = _queue(nodes=2)
    job = q.submit(NODE, walltime=None)
    q.step()
    assert job.state is JobState.RUNNING
    n = len(job.paths)
    for bad in (-2, 0, None):
        assert not q.shrink_job(job.jobid, count=bad)
        assert len(job.paths) == n          # nothing was released
    exc = [e for e in q.eventlog.for_job(job.jobid)
           if e.type.value == "exception"]
    assert len(exc) == 3
    assert all(e.detail["reason"] == "invalid shrink count" for e in exc)
    # a positive count still shrinks
    assert q.shrink_job(job.jobid, count=1)
    assert len(job.paths) == n - 1
    assert q.scheduler.graph.validate_tree()


def test_graph_version_bumps_on_match_relevant_mutations():
    """Equal ``graph.version`` must guarantee equal match results: every
    free-flip, status-flip, and structural edit bumps it; pure reads
    and no-op mutations do not."""
    q = _queue(nodes=2)
    g = q.scheduler.graph
    v0 = g.version
    job = q.submit(NODE, walltime=5.0)
    q.step()                            # alloc: free flips -> bump
    assert job.state is JobState.RUNNING
    v1 = g.version
    assert v1 > v0
    assert g.validate_tree() and g.version == v1     # reads: no bump
    q.advance(5.0)                      # release: free flips -> bump
    assert g.version > v1
    v2 = g.version
    g.set_status(g.roots[0], "down")
    assert g.version > v2
    g.set_status(g.roots[0], "up")
    v3 = g.version
    g.set_status(g.roots[0], "up")      # no-op status: no bump
    assert g.version == v3


def test_failed_match_memo_skips_and_invalidates():
    """A job that failed to match is not re-matched until the graph
    changes; a release (or an external kick()) re-arms it."""
    q = _queue(nodes=1)
    a = q.submit(NODE, walltime=10.0)
    b = q.submit(NODE, walltime=10.0)
    q.step()
    assert a.state is JobState.RUNNING and b.state is JobState.PENDING
    g = q.scheduler.graph
    assert b.nogo_version == g.version   # memoized at current version
    # idle re-steps do not clear the memo (graph unchanged)
    q.kick()                             # kick clears it (contract:
    assert b.nogo_version is None        # out-of-band Job mutation)
    q.step()
    assert b.state is JobState.PENDING   # still does not fit
    assert b.nogo_version == g.version   # re-memoized
    q.advance(10.0)                      # a completes -> version moves
    assert b.state is JobState.RUNNING   # memo did not block the start
    q.advance(10.0)
    assert b.state is JobState.COMPLETED


def test_easy_backfill_window_bounds_candidates():
    """``EasyBackfill(max_candidates=k)`` examines at most k pending
    jobs per pass; unbounded EASY backfills deeper."""
    from repro.core import EasyBackfill

    def run(max_candidates):
        g = build_cluster(nodes=2)
        sched = SchedulerInstance("w", g)
        q = JobQueue(sched, clock=SimClock(), backfill=True,
                     policy=EasyBackfill(max_candidates=max_candidates))
        # head needs both nodes and must wait for the wide job; the
        # singles behind it are backfill food
        wide = q.submit(Jobspec.hpc(nodes=2, sockets=2, cores=32),
                        walltime=5.0)
        q.step()
        assert wide.state is JobState.RUNNING
        head = q.submit(Jobspec.hpc(nodes=2, sockets=2, cores=32),
                        walltime=5.0, priority=9)
        small = Jobspec.hpc(nodes=0, sockets=1, cores=4)
        fillers = [q.submit(small, walltime=1.0) for _ in range(6)]
        q.step()
        assert head.state is JobState.PENDING
        return sum(j.state is JobState.RUNNING for j in fillers)

    assert run(max_candidates=None) > run(max_candidates=1) == 1


# ---------------------------------------------------------------------- #
# reservation ledger lifecycle (core/policy.ReservationLedger)
# ---------------------------------------------------------------------- #
def _ledger_agrees(q):
    """The ledger's entries must mirror the running set exactly: one
    entry per walltimed running job, carrying its end_time and bound
    path type counts."""
    from repro.core.policy import _path_type_counts
    want = {j.jobid: (j.end_time, _path_type_counts(q, j))
            for j in q.running if j.end_time is not None}
    assert q.ledger._entries == want, (q.ledger._entries, want)


def test_ledger_tracks_start_finish_cancel():
    q = _queue(nodes=2)
    a = q.submit(NODE, walltime=10.0)
    b = q.submit(NODE, walltime=20.0)
    q.step()
    assert a.state is JobState.RUNNING and b.state is JobState.RUNNING
    _ledger_agrees(q)
    assert q.cancel(b.jobid)
    _ledger_agrees(q)
    q.advance(10.0)
    assert a.state is JobState.COMPLETED
    _ledger_agrees(q)
    assert q.ledger._entries == {}


def test_ledger_tracks_grow_and_shrink():
    q = _queue(nodes=2)
    job = q.submit(NODE, walltime=50.0)
    q.step()
    assert job.state is JobState.RUNNING
    _ledger_agrees(q)
    n = len(job.paths)
    assert q.shrink_job(job.jobid, count=4)
    assert len(job.paths) == n - 4
    _ledger_agrees(q)
    assert q.grow_job(job.jobid, Jobspec.hpc(nodes=0, sockets=1,
                                             cores=4))
    _ledger_agrees(q)
    q.drain()
    assert q.ledger._entries == {}


def test_ledger_tracks_preemption():
    from repro.core import PreemptivePriority
    g = build_cluster(nodes=1)
    q = JobQueue(SchedulerInstance("lp", g), clock=SimClock(),
                 policy=PreemptivePriority())
    low = q.submit(NODE, walltime=50.0, priority=0, preemptible=True)
    q.step()
    assert low.state is JobState.RUNNING
    _ledger_agrees(q)
    hi = q.submit(NODE, walltime=10.0, priority=5)
    q.step()
    assert low.state is JobState.PREEMPTED
    assert hi.state is JobState.RUNNING
    _ledger_agrees(q)               # victim's entry gone, winner's in
    q.drain()
    assert low.state is JobState.COMPLETED
    assert q.ledger._entries == {}


def test_kick_clears_prefilter_and_backfill_memos():
    """kick()'s contract covers the new memo fields too: out-of-band
    Job mutation re-arms the prefilter and EASY skip memos alongside
    the failed-match memo."""
    q = _queue(nodes=1)
    a = q.submit(NODE, walltime=10.0)
    b = q.submit(NODE, walltime=10.0)
    q.step()
    assert b.state is JobState.PENDING
    b._pf_version, b._pf_ok = 123, False
    b._bf_version, b._bf_head = 123, 456
    q.kick()
    assert b.nogo_version is None
    assert b._pf_version is None and b._bf_version is None


# ---------------------------------------------------------------------- #
# columnar pending mirror (core/policy._PendingMirror)
# ---------------------------------------------------------------------- #
def _mirror_agrees(q):
    """Mirror live rows must equal the pending list, column for column."""
    import numpy as np
    mir = q._pmirror
    live = {}
    for i, j in enumerate(mir.jobs):
        if j is None:
            continue
        assert mir.slot[j.jobid] == i
        spec, grow, prio = mir.sig_entries[int(mir.sig[i])]
        assert spec is j.jobspec and grow == j.grow and prio == j.priority
        wt = mir.wt[i]
        assert (j.walltime is None and np.isnan(wt)) or wt == j.walltime
        assert mir.prio[i] == j.priority and mir.seq[i] == j.seq
        live[j.jobid] = j
    assert live == {j.jobid: j for j in q.pending}


def test_pending_mirror_tracks_queue_churn():
    """The columnar mirror the vectorized exact-EASY pass reads must
    stay in sync with ``queue.pending`` through every lifecycle edge:
    submit, start, cancel, preemption requeue, and kick's resync."""
    from repro.core import PreemptivePriority
    g = build_cluster(nodes=1)
    q = JobQueue(SchedulerInstance("pm", g), clock=SimClock(),
                 policy=PreemptivePriority())
    low = q.submit(NODE, walltime=30.0, priority=0, preemptible=True)
    fillers = [q.submit(NODE, walltime=5.0) for _ in range(4)]
    q.submit(NODE)                   # walltime None -> NaN column
    q.step()
    _mirror_agrees(q)
    assert q.cancel(fillers[0].jobid)
    _mirror_agrees(q)
    hi = q.submit(NODE, walltime=10.0, priority=5)
    q.step()                         # preempts low -> requeued
    assert low.state is JobState.PREEMPTED
    assert hi.state is JobState.RUNNING
    _mirror_agrees(q)
    q.kick()                         # full-resync path
    _mirror_agrees(q)
    for _ in range(12):
        q.advance(10.0)
    _mirror_agrees(q)


def test_pending_mirror_compacts_tombstones():
    """Discards tombstone rather than shift; once tombstones dominate
    the mirror compacts down to the live set."""
    q = _queue(nodes=1)
    blocker = q.submit(NODE, walltime=500.0)
    q.step()
    assert blocker.state is JobState.RUNNING
    jobs = [q.submit(NODE, walltime=1.0) for _ in range(80)]
    # 80 live rows + the started blocker's tombstone
    assert q._pmirror.n == 81 and q._pmirror.holes == 1
    for j in jobs:
        assert q.cancel(j.jobid)
    _mirror_agrees(q)
    # compacted at least once; tombstone residue stays bounded
    assert q._pmirror.n < 80
    assert q._pmirror.holes <= 32 or \
        q._pmirror.holes * 2 <= q._pmirror.n
