"""RPC transport tests (two regimes)."""
import pytest

from repro.core.rpc import (InProcTransport, RPCServer, SocketTransport,
                            _decode_frame, _encode_frame, pack_json,
                            unpack_json)


def test_frame_codec_roundtrip():
    m, p = _decode_frame(_encode_frame("match_grow", b"payload-bytes"))
    assert m == "match_grow" and p == b"payload-bytes"


def test_inproc_transport():
    t = InProcTransport(lambda m, p: (m + ":").encode() + p)
    assert t.call("x", b"abc") == b"x:abc"
    assert t.regime == "intranode"


def test_socket_transport_roundtrip():
    srv = RPCServer(lambda m, p: p[::-1])
    try:
        t = SocketTransport(srv.address)
        assert t.call("rev", b"abcdef") == b"fedcba"
        # larger payloads (multi-frame reads)
        big = bytes(range(256)) * 4096
        assert t.call("rev", big) == big[::-1]
        t.close()
    finally:
        srv.close()


def test_socket_transport_pools_connections():
    """Concurrent calls each get their own pooled connection (no
    serialization on one socket), the pool never exceeds its bound,
    and connections are reused across sequential calls."""
    import threading

    srv = RPCServer(lambda m, p: p[::-1])
    try:
        t = SocketTransport(srv.address, pool_size=2)
        try:
            results = {}

            def worker(i):
                payload = bytes([i]) * 1024
                results[i] = t.call("rev", payload) == payload[::-1]

            threads = [threading.Thread(target=worker, args=(i,))
                       for i in range(8)]
            for th in threads:
                th.start()
            for th in threads:
                th.join()
            assert all(results[i] for i in range(8))
            assert len(t._pool) <= 2        # surplus closed on check-in
            # sequential calls reuse the pooled connection
            before = t._pool[0]
            assert t.call("rev", b"ab") == b"ba"
            assert t._pool[0] is before
        finally:
            t.close()
    finally:
        srv.close()


def test_json_helpers():
    d = {"jobspec": {"resources": [{"type": "core", "count": 4}]}}
    assert unpack_json(pack_json(d)) == d
    assert unpack_json(b"") == {}


def test_method_registry_dispatch():
    from repro.core.rpc import MethodRegistry
    reg = MethodRegistry()
    reg.register("echo", lambda p: p)
    reg.register("rev", lambda p: p[::-1])
    assert "echo" in reg and reg.methods() == ("echo", "rev")
    assert reg("echo", b"x") == b"x"
    assert reg("rev", b"ab") == b"ba"
    with pytest.raises(ValueError, match="unknown RPC method"):
        reg("nope", b"")
    reg.unregister("rev")
    assert "rev" not in reg


def test_scheduler_registers_methods_and_extension():
    from repro.core import SchedulerInstance, build_cluster
    from repro.core.rpc import pack_json, unpack_json
    inst = SchedulerInstance("s", build_cluster(nodes=1))
    assert {"match_grow", "release", "reclaim"} <= set(inst.methods.methods())
    inst.register_method(
        "status", lambda p: pack_json({"free": inst.graph.vertex(
            inst.graph.roots[0]).agg_free}))
    t = inst.inproc_transport()
    out = unpack_json(t.call("status", b""))
    assert out["free"]["core"] == 32
    with pytest.raises(ValueError):
        t.call("bogus", b"")
