"""RPC transport tests (two regimes)."""
import pytest

from repro.core.rpc import (InProcTransport, RPCServer, SocketTransport,
                            _decode_frame, _encode_frame, pack_json,
                            unpack_json)


def test_frame_codec_roundtrip():
    m, p = _decode_frame(_encode_frame("match_grow", b"payload-bytes"))
    assert m == "match_grow" and p == b"payload-bytes"


def test_inproc_transport():
    t = InProcTransport(lambda m, p: (m + ":").encode() + p)
    assert t.call("x", b"abc") == b"x:abc"
    assert t.regime == "intranode"


def test_socket_transport_roundtrip():
    srv = RPCServer(lambda m, p: p[::-1])
    try:
        t = SocketTransport(srv.address)
        assert t.call("rev", b"abcdef") == b"fedcba"
        # larger payloads (multi-frame reads)
        big = bytes(range(256)) * 4096
        assert t.call("rev", big) == big[::-1]
        t.close()
    finally:
        srv.close()


def test_json_helpers():
    d = {"jobspec": {"resources": [{"type": "core", "count": 4}]}}
    assert unpack_json(pack_json(d)) == d
    assert unpack_json(b"") == {}
