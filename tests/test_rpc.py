"""RPC transport tests (two regimes + the multiplexed path)."""
import socket
import threading
import time

import pytest

from repro.core.rpc import (_HDR, ClientReactor, InProcTransport,
                            MuxServer, MuxTransport, ProtocolError,
                            RPCError, RPCServer, SocketTransport,
                            _decode_frame, _encode_frame, pack_json,
                            unpack_json)


def test_frame_codec_roundtrip():
    m, p = _decode_frame(_encode_frame("match_grow", b"payload-bytes"))
    assert m == "match_grow" and p == b"payload-bytes"


def test_inproc_transport():
    t = InProcTransport(lambda m, p: (m + ":").encode() + p)
    assert t.call("x", b"abc") == b"x:abc"
    assert t.regime == "intranode"


def test_socket_transport_roundtrip():
    srv = RPCServer(lambda m, p: p[::-1])
    try:
        t = SocketTransport(srv.address)
        assert t.call("rev", b"abcdef") == b"fedcba"
        # larger payloads (multi-frame reads)
        big = bytes(range(256)) * 4096
        assert t.call("rev", big) == big[::-1]
        t.close()
    finally:
        srv.close()


def test_socket_transport_pools_connections():
    """Concurrent calls each get their own pooled connection (no
    serialization on one socket), the pool never exceeds its bound,
    and connections are reused across sequential calls."""
    import threading

    srv = RPCServer(lambda m, p: p[::-1])
    try:
        t = SocketTransport(srv.address, pool_size=2)
        try:
            results = {}

            def worker(i):
                payload = bytes([i]) * 1024
                results[i] = t.call("rev", payload) == payload[::-1]

            threads = [threading.Thread(target=worker, args=(i,))
                       for i in range(8)]
            for th in threads:
                th.start()
            for th in threads:
                th.join()
            assert all(results[i] for i in range(8))
            assert len(t._pool) <= 2        # surplus closed on check-in
            # sequential calls reuse the pooled connection
            before = t._pool[0]
            assert t.call("rev", b"ab") == b"ba"
            assert t._pool[0] is before
        finally:
            t.close()
    finally:
        srv.close()


def test_max_frame_enforced_on_client():
    """A corrupt/hostile length prefix from the server must raise a
    clean ProtocolError, never attempt the allocation."""
    lst = socket.socket()
    lst.bind(("127.0.0.1", 0))
    lst.listen(1)

    def evil_server():
        conn, _ = lst.accept()
        conn.recv(65536)                      # swallow the request
        conn.sendall(_HDR.pack(0xFFFFFFFF))   # 4 GiB "response"
        time.sleep(0.5)
        conn.close()

    th = threading.Thread(target=evil_server, daemon=True)
    th.start()
    t = SocketTransport(lst.getsockname(), max_frame=1 << 20)
    with pytest.raises(ProtocolError, match="exceeds max_frame"):
        t.call("x", b"hi")
    t.close()
    lst.close()
    th.join(timeout=2)


def test_max_frame_enforced_on_server():
    """A client announcing an oversized frame gets disconnected (both
    server implementations), not a multi-GiB buffer."""
    for srv in (RPCServer(lambda m, p: p, max_frame=1 << 16),
                MuxServer(lambda m, p: p, max_frame=1 << 16)):
        try:
            c = socket.create_connection(srv.address)
            c.sendall(_HDR.pack(1 << 24))     # 16 MiB > 64 KiB limit
            c.settimeout(2.0)
            assert c.recv(1) == b""           # server hung up
            c.close()
        finally:
            srv.close()


def test_mux_rejects_oversized_frame_from_server_push():
    """MuxTransport applies the same bound on its reader path."""
    srv = MuxServer(lambda m, p: b"x" * (1 << 18))
    try:
        t = MuxTransport(srv.address, max_frame=1 << 16)
        with pytest.raises((ProtocolError, ConnectionError)):
            t.call("big", b"")
        t.close()
    finally:
        srv.close()


def test_rpcserver_close_joins_all_sessions():
    """close() must unblock sessions parked in recv and join every
    session thread before returning — no lingering threads."""
    srv = RPCServer(lambda m, p: p)
    transports = [SocketTransport(srv.address) for _ in range(4)]
    for t in transports:
        assert t.call("echo", b"ok") == b"ok"
    with srv._lock:
        threads = [th for th, _ in srv._sessions.values()]
    assert len(threads) == 4 and all(th.is_alive() for th in threads)
    srv.close()
    assert all(not th.is_alive() for th in threads)
    assert not srv._thread.is_alive()
    for t in transports:
        t.close()


def test_rpcserver_backlog_configurable():
    srv = RPCServer(lambda m, p: p, backlog=64)
    try:
        t = SocketTransport(srv.address)
        assert t.call("x", b"y") == b"y"
        t.close()
    finally:
        srv.close()


def test_mid_frame_peer_close_client_side():
    """Server dies mid-response: the client surfaces ConnectionError
    instead of hanging or returning a short read."""
    lst = socket.socket()
    lst.bind(("127.0.0.1", 0))
    lst.listen(1)

    def half_server():
        conn, _ = lst.accept()
        conn.recv(65536)
        conn.sendall(_HDR.pack(1000) + b"x" * 10)   # 10 of 1000 bytes
        conn.close()

    th = threading.Thread(target=half_server, daemon=True)
    th.start()
    t = SocketTransport(lst.getsockname())
    with pytest.raises((ConnectionError, OSError)):
        t.call("x", b"req")
    t.close()
    lst.close()
    th.join(timeout=2)


def test_mid_frame_peer_close_server_side():
    """Client dies mid-request: both servers drop the session cleanly
    and keep serving other clients."""
    for srv in (RPCServer(lambda m, p: p), MuxServer(lambda m, p: p)):
        try:
            c = socket.create_connection(srv.address)
            frame = _encode_frame("x", b"y" * 100)
            c.sendall(_HDR.pack(len(frame)) + frame[:5])   # truncated
            c.close()
            t = SocketTransport(srv.address)
            assert t.call("ok", b"alive") == b"alive"
            t.close()
        finally:
            srv.close()


def test_mux_pipelined_out_of_order_correlation():
    """Responses land out of order (slow call issued first); the
    request-id correlation must route each to its caller."""
    def handler(method, payload):
        if method == "slow":
            time.sleep(0.2)
        return method.encode() + b":" + payload

    srv = MuxServer(handler, workers=4)
    try:
        t = MuxTransport(srv.address)
        results = {}

        def call(method, payload):
            results[method] = t.call(method, payload)

        slow = threading.Thread(target=call, args=("slow", b"a"))
        slow.start()
        time.sleep(0.05)            # slow call is in flight
        assert t.call("fast", b"b") == b"fast:b"   # overtakes it
        slow.join(timeout=2)
        assert results["slow"] == b"slow:a"
        # call_many pipelines a whole batch on one connection
        out = t.call_many([("m%d" % i, bytes([i])) for i in range(50)])
        assert out == [b"m%d:" % i + bytes([i]) for i in range(50)]
        t.close()
    finally:
        srv.close()


def test_mux_error_frame_raises_rpcerror():
    def handler(method, payload):
        raise ValueError("no such thing")
    srv = MuxServer(handler)
    try:
        t = MuxTransport(srv.address)
        with pytest.raises(RPCError, match="no such thing"):
            t.call("x", b"")
        # the connection survives an application error
        srv2_alive = True
        with pytest.raises(RPCError):
            t.call("y", b"")
        assert srv2_alive
        t.close()
    finally:
        srv.close()


def test_mux_eof_fails_pending_calls():
    """Server close fails every in-flight call promptly."""
    srv = MuxServer(lambda m, p: (time.sleep(1.5), p)[1])
    t = MuxTransport(srv.address)
    errs = []

    def call():
        try:
            t.call("hang", b"")
        except (ConnectionError, OSError) as e:
            errs.append(e)

    th = threading.Thread(target=call)
    th.start()
    time.sleep(0.1)
    srv.close()
    th.join(timeout=3)
    assert not th.is_alive() and len(errs) == 1
    t.close()


def test_client_reactor_services_many_transports():
    """Many MuxTransports share one reactor thread."""
    srv = MuxServer(lambda m, p: p[::-1])
    reactor = ClientReactor()
    try:
        ts = [MuxTransport(srv.address, reactor=reactor)
              for _ in range(16)]
        for i, t in enumerate(ts):
            assert t.call("rev", bytes([i]) * 8) == bytes([i]) * 8
        for t in ts:
            t.close()
    finally:
        reactor.close()
        srv.close()


def test_legacy_transport_against_mux_server():
    """The compatibility/oracle path: pooled blocking SocketTransport
    works unchanged against the multiplexed server."""
    srv = MuxServer(lambda m, p: p[::-1])
    try:
        t = SocketTransport(srv.address, pool_size=2)
        big = bytes(range(256)) * 4096
        assert t.call("rev", big) == big[::-1]
        results = {}

        def worker(i):
            payload = bytes([i]) * 512
            results[i] = t.call("rev", payload) == payload[::-1]

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(8)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        assert all(results.values())
        t.close()
    finally:
        srv.close()


def test_mux_server_deterministic_close():
    srv = MuxServer(lambda m, p: p)
    t = MuxTransport(srv.address)
    assert t.call("x", b"1") == b"1"
    srv.close()
    assert not srv._loop_thread.is_alive()
    assert all(not w.is_alive() for w in srv._workers)
    t.close()


def test_json_helpers():
    d = {"jobspec": {"resources": [{"type": "core", "count": 4}]}}
    assert unpack_json(pack_json(d)) == d
    assert unpack_json(b"") == {}


def test_method_registry_dispatch():
    from repro.core.rpc import MethodRegistry
    reg = MethodRegistry()
    reg.register("echo", lambda p: p)
    reg.register("rev", lambda p: p[::-1])
    assert "echo" in reg and reg.methods() == ("echo", "rev")
    assert reg("echo", b"x") == b"x"
    assert reg("rev", b"ab") == b"ba"
    with pytest.raises(ValueError, match="unknown RPC method"):
        reg("nope", b"")
    reg.unregister("rev")
    assert "rev" not in reg


def test_scheduler_registers_methods_and_extension():
    from repro.core import SchedulerInstance, build_cluster
    from repro.core.rpc import pack_json, unpack_json
    inst = SchedulerInstance("s", build_cluster(nodes=1))
    assert {"match_grow", "release", "reclaim"} <= set(inst.methods.methods())
    inst.register_method(
        "status", lambda p: pack_json({"free": inst.graph.vertex(
            inst.graph.roots[0]).agg_free}))
    t = inst.inproc_transport()
    out = unpack_json(t.call("status", b""))
    assert out["free"]["core"] == 32
    with pytest.raises(ValueError):
        t.call("bogus", b"")
