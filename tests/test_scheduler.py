"""Scheduler tests: MA / MG (Algorithm 1), shrink, hierarchy, external."""

from repro.core import (Jobspec, ResourceReq, SchedulerInstance,
                        SimulatedEC2Provider, TPUSliceProvider, build_chain,
                        build_cluster)


def _levels(paper=True):
    """Paper Table-2 level graphs (L0..L4)."""
    sizes = [(128, 2, 16), (8, 2, 16), (4, 2, 16), (2, 2, 16), (1, 2, 16)]
    return [build_cluster(nodes=n, sockets_per_node=s, cores_per_socket=c)
            for n, s, c in sizes]


def test_jobspec_table1_sizes():
    want = {(64, 128, 2048): 4480, (32, 64, 1024): 2240, (16, 32, 512): 1120,
            (8, 16, 256): 560, (4, 8, 128): 280, (2, 4, 64): 140,
            (1, 2, 32): 70, (0, 1, 16): 36}
    for (n, s, c), size in want.items():
        assert Jobspec.hpc(nodes=n, sockets=s, cores=c).graph_size() == size


def test_match_allocate_exclusive():
    g = build_cluster(nodes=4)
    sched = SchedulerInstance("L0", g)
    a1 = sched.match_allocate(Jobspec.hpc(nodes=2, sockets=4, cores=64))
    a2 = sched.match_allocate(Jobspec.hpc(nodes=2, sockets=4, cores=64))
    assert a1 and a2
    assert not (set(a1.paths) & set(a2.paths))
    a3 = sched.match_allocate(Jobspec.hpc(nodes=1, sockets=2, cores=32))
    assert a3 is None  # cluster exhausted


def test_match_grow_local():
    g = build_cluster(nodes=2)
    sched = SchedulerInstance("L0", g)
    alloc = sched.match_allocate(Jobspec.hpc(nodes=1, sockets=2, cores=32),
                                 jobid="j")
    assert alloc
    sub = sched.match_grow(Jobspec.hpc(nodes=1, sockets=2, cores=32), "j")
    assert sub and sub.via == "local"
    rec = sched.timings[-1]
    assert rec.matched_locally and rec.t_comms == 0
    # all resources joined the SAME job
    assert len(sched.allocations["j"].paths) == 70


def test_nested_match_grow_chain():
    graphs = _levels()
    h = build_chain(graphs, socket_levels=[1])
    try:
        leaf = h.leaf
        # make L1..L4 fully allocated so requests recurse to L0
        for inst in h.instances[1:]:
            n = len(inst.graph.by_type("node"))
            assert inst.match_allocate(
                Jobspec.hpc(nodes=n, sockets=2 * n, cores=32 * n),
                jobid="init")
        sub = leaf.match_grow(Jobspec.hpc(nodes=1, sockets=2, cores=32),
                              "init")
        assert sub and sub.via == "parent"

        # the leaf's graph grew by the matched subgraph
        assert len(leaf.graph.by_type("node")) == 2
        assert leaf.graph.validate_tree()
        # every level on the path recorded a timing
        levels = {t.level for inst in h.instances for t in inst.timings}
        assert {"L0", "L1", "L2", "L3", "L4"} <= levels
        # component model: match + comms + add_upd == total (by def.)
        for inst in h.instances:
            for t in inst.timings:
                assert t.total == t.t_match + t.t_comms + t.t_add_upd
    finally:
        h.close()


def test_match_shrink_bottom_up():
    g = build_cluster(nodes=2)
    sched = SchedulerInstance("L0", g)
    sched.match_allocate(Jobspec.hpc(nodes=2, sockets=4, cores=64),
                         jobid="j")
    victims = [p for p in sched.allocations["j"].paths if "/node1" in p]
    sched.match_shrink("j", victims, remove_vertices=True)
    assert all(p not in sched.graph for p in victims)
    assert sched.graph.validate_tree()


def test_external_burst_ec2():
    g = build_cluster(nodes=1)
    sched = SchedulerInstance("top", g, external=SimulatedEC2Provider())
    sched.match_allocate(Jobspec.hpc(nodes=1, sockets=2, cores=32),
                         jobid="j")
    sub = sched.match_grow(Jobspec.instances("t2.2xlarge", 2), "j")
    assert sub and sub.via == "external"
    assert sched.timings[-1].external
    assert len(sched.graph.by_type("zone")) >= 1  # zone interposition
    # E_i bookkeeping: external resources tracked separately
    assert sched.external_paths
    # releasing the job removes the external resources (E_i = G_i \ G_0)
    sched.release("j")
    assert not sched.external_paths
    assert sched.graph.validate_tree()


def test_external_specialization_at_child_level():
    """A child instance with its own provider bursts independently; the
    parent graph is untouched (supergraph-inclusion deliberately
    invalidated — paper Section 3)."""
    graphs = [build_cluster(nodes=2), build_cluster(nodes=1)]
    h = build_chain(graphs)
    try:
        child = h.leaf
        child.external = TPUSliceProvider()
        child.external_at_any_level = True
        # parent fully allocated -> parent MG fails -> child's own provider
        h.top.match_allocate(Jobspec.hpc(nodes=2, sockets=4, cores=64),
                             jobid="hog")
        child.match_allocate(Jobspec.hpc(nodes=1, sockets=2, cores=32),
                             jobid="j")
        before_parent = set(h.top.graph.paths())
        sub = child.match_grow(
            Jobspec(resources=[ResourceReq("node", 1)]), "j")
        assert sub and child.timings[-1].external
        assert set(h.top.graph.paths()) == before_parent
    finally:
        h.close()


def test_grow_then_release_returns_to_parent_pool():
    graphs = [build_cluster(nodes=2), build_cluster(nodes=1)]
    h = build_chain(graphs)
    try:
        leaf = h.leaf
        leaf.match_allocate(Jobspec.hpc(nodes=1, sockets=2, cores=32),
                            jobid="j")
        sub = leaf.match_grow(Jobspec.hpc(nodes=1, sockets=2, cores=32), "j")
        assert sub
        # parent allocated the resources to the child's job
        parent_alloc = h.top.allocations.get("j")
        assert parent_alloc and parent_alloc.paths
        leaf.match_shrink("j", [p for p in sub.paths()], remove_vertices=True)
        # parent released them back to its free pool
        g = h.top.graph
        freed = [p for p in parent_alloc.paths if p in g]
        assert all(not g.vertex(p).allocations for p in freed)
    finally:
        h.close()


def test_match_shrink_release_rpc_over_socket():
    """Bottom-up shrink over the internode regime: the leaf's shrink
    sends the release RPC through the SocketTransport to its parent,
    which returns the vertices to its free pool."""
    graphs = [build_cluster(nodes=2), build_cluster(nodes=1)]
    h = build_chain(graphs, socket_levels=[1])   # leaf->parent: socket
    try:
        leaf, top = h.leaf, h.top
        leaf.match_allocate(Jobspec.hpc(nodes=1, sockets=2, cores=32),
                            jobid="j")
        sub = leaf.match_grow(Jobspec.hpc(nodes=1, sockets=2, cores=32),
                              "j")
        assert sub and sub.via == "parent"
        held = [p for p in top.allocations["j"].paths]
        assert held
        leaf.match_shrink("j", sub.paths(), remove_vertices=True)
        # the release RPC crossed the socket: parent freed the vertices
        for p in held:
            if p in top.graph:
                assert not top.graph.vertex(p).allocations
        assert all(p not in leaf.graph for p in sub.paths())
        assert leaf.graph.validate_tree() and top.graph.validate_tree()
    finally:
        h.close()


def test_grow_then_shrink_invariants_every_transform():
    """validate_tree() holds after EVERY transform in a grow/shrink
    churn sequence, at every level of the hierarchy."""
    graphs = [build_cluster(nodes=4), build_cluster(nodes=1)]
    h = build_chain(graphs, socket_levels=[1])
    try:
        leaf, top = h.leaf, h.top

        def check():
            assert leaf.graph.validate_tree()
            assert top.graph.validate_tree()

        leaf.match_allocate(Jobspec.hpc(nodes=1, sockets=2, cores=32),
                            jobid="j")
        check()
        grown = []
        for _ in range(3):
            sub = leaf.match_grow(
                Jobspec.hpc(nodes=1, sockets=2, cores=32), "j")
            assert sub
            grown.append(sub.paths())
            check()
        # shrink back in reverse order, one grow at a time
        for paths in reversed(grown):
            leaf.match_shrink("j", paths, remove_vertices=True)
            check()
        # the leaf is back to its own single node
        assert len(leaf.graph.by_type("node")) == 1
        # the parent's pool is fully free again
        freed = [p for p in top.graph.paths() if "/node" in p]
        assert all(not top.graph.vertex(p).allocations for p in freed)
    finally:
        h.close()


def test_release_external_paths_subset():
    """Partial release with external resources present: only the
    released subset of E_i disappears (set bookkeeping, not O(n^2))."""
    g = build_cluster(nodes=1)
    sched = SchedulerInstance("top", g, external=SimulatedEC2Provider())
    sched.match_allocate(Jobspec.hpc(nodes=1, sockets=2, cores=32), "j")
    s1 = sched.match_grow(Jobspec.instances("t2.small", 1), "j")
    s2 = sched.match_grow(Jobspec.instances("t2.small", 1), "j")
    assert s1 and s2
    assert isinstance(sched.external_paths, set)
    before = set(sched.external_paths)
    sched.release("j", s1.paths())
    assert sched.external_paths == before - set(s1.paths())
    assert sched.graph.validate_tree()
