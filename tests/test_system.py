"""End-to-end system behaviour tests (the paper's three capabilities)."""

from repro.core import (Jobspec, ResourceReq, SchedulerInstance,
                        SimulatedEC2Provider, build_chain, build_cluster)


def test_capability_1_rjms_dynamism():
    """Elastic job: grow then shrink a running allocation."""
    g = build_cluster(nodes=4)
    sched = SchedulerInstance("L0", g)
    sched.match_allocate(Jobspec.hpc(nodes=1, sockets=2, cores=32), "job")
    assert len(sched.allocations["job"].paths) == 35
    sched.match_grow(Jobspec.hpc(nodes=2, sockets=4, cores=64), "job")
    assert len(sched.allocations["job"].paths) == 35 * 3
    victims = sched.allocations["job"].paths[-35:]
    sched.match_shrink("job", victims, remove_vertices=False)
    sched.release("job", victims)
    assert len(sched.allocations["job"].paths) == 35 * 2
    assert g.validate_tree()


def test_capability_2_external_integration():
    """Cloud bursting: fleet resources chosen BY THE PROVIDER integrate
    into the running allocation with zone placement info."""
    g = build_cluster(nodes=1)
    sched = SchedulerInstance("top", g,
                              external=SimulatedEC2Provider(seed=3))
    sched.match_allocate(Jobspec.hpc(nodes=1, sockets=2, cores=32), "job")
    sub = sched.match_grow(Jobspec.fleet(10), "job")
    assert sub
    zones = {sched.graph.vertex(n).properties.get("zone")
             for n in sched.graph.by_type("node")
             if sched.graph.vertex(n).properties.get("provider") == "aws"}
    assert len(zones) >= 2  # location-aware integration
    assert g.validate_tree()


def test_capability_3_orchestrator_tasks():
    """KubeFlux-style: schedule many pod-sized tasks via MA, then scale
    the set elastically via MG."""
    g = build_cluster(nodes=8, sockets_per_node=2, cores_per_socket=20)
    sched = SchedulerInstance("kubeflux", g)
    pod_req = Jobspec(resources=[ResourceReq("core", 4)])
    pods = []
    for i in range(10):
        a = sched.match_allocate(pod_req, jobid=f"pod-{i}")
        assert a is not None
        pods.append(a)
    replicaset = sched.match_allocate(pod_req, jobid="rs")
    for _ in range(9):
        assert sched.match_grow(pod_req, "rs")
    assert len(sched.allocations["rs"].paths) == 40
    assert g.validate_tree()


def test_combined_all_three():
    """The paper's thesis: all three combined in one scenario — a nested
    job grows locally, exhausts the cluster, bursts to the cloud, then
    shrinks back."""
    graphs = [build_cluster(nodes=2), build_cluster(nodes=1)]
    h = build_chain(graphs, external=SimulatedEC2Provider())
    try:
        leaf = h.leaf
        leaf.match_allocate(Jobspec.hpc(nodes=1, sockets=2, cores=32), "j")
        # local growth through the hierarchy
        assert leaf.match_grow(
            Jobspec.hpc(nodes=1, sockets=2, cores=32), "j")
        # cluster exhausted -> top level bursts via ExternalAPI
        h.top.match_allocate(Jobspec.hpc(nodes=1, sockets=2, cores=32),
                             "hog")
        sub = leaf.match_grow(Jobspec.instances("t2.2xlarge", 1), "j")
        assert sub
        assert any("t2-2xlarge" in p for p in leaf.graph.paths())
        # shrink the external part back out
        ext = [p for p in sub.paths() if "t2-2xlarge" in p]
        leaf.match_shrink("j", ext, remove_vertices=True)
        assert all(p not in leaf.graph for p in ext)
        assert leaf.graph.validate_tree()
    finally:
        h.close()
