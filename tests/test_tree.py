"""Tree-shaped hierarchies: multi-child instances and sibling routing.

The paper's Fig. 2 multi-user topology: a parent with several child
subtrees.  A child's failed MATCHGROW is routed by the parent to the
child's *siblings* (the ``reclaim`` RPC) before escalating to the
parent's own parent or the External API.
"""
import pytest

from repro.core import (GrowResult, Jobspec, SchedulerInstance, TreeSpec,
                        build_cluster, build_tree)


def _delegated_tree(socket=False):
    """Root owns 8 nodes; users A and B each get a 4-node subtree
    (disjoint node sets, same path space — subgraph inclusion), and the
    root marks everything delegated so it has no free pool of its own."""
    root_g = build_cluster(nodes=8)
    a_g = root_g.extract([p for p in root_g.paths()
                          if any(f"node{i}" in p for i in (0, 1, 2, 3))])
    b_g = root_g.extract([p for p in root_g.paths()
                          if any(f"node{i}" in p for i in (4, 5, 6, 7))])
    for g in (a_g, b_g):
        g.init_aggregates()
    spec = TreeSpec(root_g, name="root", children=[
        TreeSpec(a_g, name="userA", socket=socket,
                 children=[TreeSpec(build_cluster(nodes=1), name="leafA",
                                    socket=socket)]),
        TreeSpec(b_g, name="userB"),
    ])
    h = build_tree(spec)
    root = h["root"]
    root.graph.set_allocated(
        [p for p in root.graph.paths() if "/node" in p], "delegated")
    return h


def test_build_tree_shape():
    h = _delegated_tree()
    try:
        root, a, b = h["root"], h["userA"], h["userB"]
        assert set(root.children) == {"userA", "userB"}
        assert set(a.children) == {"leafA"}
        assert b.children == {}
        assert h.top is root
        # preorder: leafA is under userA, userB last
        assert [i.name for i in h.instances] == \
            ["root", "userA", "leafA", "userB"]
        assert a.graph.is_subgraph_of(root.graph)
        assert b.graph.is_subgraph_of(root.graph)
    finally:
        h.close()


@pytest.mark.parametrize("socket", [False, True])
def test_sibling_routing_three_levels(socket):
    """leafA's MG fails locally and at userA; the root (fully delegated)
    reclaims from userB's free subtree instead of failing."""
    h = _delegated_tree(socket=socket)
    try:
        root, a, b, leaf = h["root"], h["userA"], h["userB"], h["leafA"]
        # userA and leafA fully allocated -> the request must escalate
        assert a.match_allocate(
            Jobspec.hpc(nodes=4, sockets=8, cores=128), jobid="hogA")
        assert leaf.match_allocate(
            Jobspec.hpc(nodes=1, sockets=2, cores=32), jobid="j")
        b_nodes_before = len(b.graph.by_type("node"))
        res = leaf.match_grow(Jobspec.hpc(nodes=1, sockets=2, cores=32),
                              "j")
        assert isinstance(res, GrowResult) and res
        assert res.via == "parent"          # from the leaf's viewpoint
        # the root recorded the sibling route
        assert root.timings[-1].via_sibling == "userB"
        # donor shrank (subtractive, bottom-up), receiver grew
        assert len(b.graph.by_type("node")) == b_nodes_before - 1
        assert len(leaf.graph.by_type("node")) == 2
        # every graph in the tree stays a valid aggregate-correct tree
        for inst in h.instances:
            assert inst.graph.validate_tree(), inst.name
        # the donated vertices are bound to the job at leaf AND root
        for p in res.paths():
            assert leaf.graph.vertex(p).allocations.get("j")
            assert root.graph.vertex(p).allocations.get("j")
    finally:
        h.close()


def test_sibling_preferred_over_external():
    """With a free sibling available, the root must not burst."""
    from repro.core import SimulatedEC2Provider
    root_g = build_cluster(nodes=2)
    a_g = root_g.extract([p for p in root_g.paths() if "node0" in p])
    b_g = root_g.extract([p for p in root_g.paths() if "node1" in p])
    h = build_tree(TreeSpec(root_g, name="root",
                            external=SimulatedEC2Provider(),
                            children=[TreeSpec(a_g, name="A"),
                                      TreeSpec(b_g, name="B")]))
    try:
        root, a = h["root"], h["A"]
        root.graph.set_allocated(
            [p for p in root.graph.paths() if "/node" in p], "delegated")
        a.match_allocate(Jobspec.hpc(nodes=1, sockets=2, cores=32), "j")
        res = a.match_grow(Jobspec.hpc(nodes=1, sockets=2, cores=32), "j")
        assert res
        assert root.timings[-1].via_sibling == "B"
        assert not root.timings[-1].external
        assert not root.external_paths
    finally:
        h.close()


def test_sibling_exhausted_falls_through_to_external():
    from repro.core import SimulatedEC2Provider
    root_g = build_cluster(nodes=1)
    a_g = root_g.extract(list(root_g.paths()))
    a_g.init_aggregates()
    h = build_tree(TreeSpec(root_g, name="root",
                            external=SimulatedEC2Provider(),
                            children=[TreeSpec(a_g, name="A")]))
    try:
        root, a = h["root"], h["A"]
        root.graph.set_allocated(
            [p for p in root.graph.paths() if "/node" in p], "delegated")
        a.match_allocate(Jobspec.hpc(nodes=1, sockets=2, cores=32), "j")
        res = a.match_grow(Jobspec.instances("t2.2xlarge", 1), "j")
        assert res                      # no sibling exists: burst
        assert root.timings[-1].external
        assert root.timings[-1].via_sibling is None
    finally:
        h.close()


def test_reclaim_rpc_direct():
    """The donor-side reclaim: matched subgraph leaves the donor."""
    g = build_cluster(nodes=2)
    inst = SchedulerInstance("donor", g)
    out = inst.engine.reclaim(Jobspec.hpc(nodes=1, sockets=2, cores=32))
    assert out is not None
    assert len(out["paths"]) == 35
    assert all(p not in inst.graph for p in out["paths"])
    assert inst.graph.validate_tree()
    # nothing left for a second whole-node claim of the same shape x2
    assert inst.engine.reclaim(
        Jobspec.hpc(nodes=2, sockets=4, cores=64)) is None


def test_reclaim_never_steals_live_job_allocation():
    """Sibling reclaim displaces delegation markers only: a vertex a
    parent allocated to a LIVE job keeps that binding (the new jobid is
    added alongside, conflict visible) — release bookkeeping for the
    prior owner must survive."""
    root_g = build_cluster(nodes=2)
    b_g = root_g.extract([p for p in root_g.paths() if "node1" in p])
    h = build_tree(TreeSpec(root_g, name="root",
                            children=[TreeSpec(build_cluster(nodes=1),
                                               name="A"),
                                      TreeSpec(b_g, name="B")]))
    try:
        root = h["root"]
        # discipline violation on purpose: root allocates BOTH nodes to
        # its own live job Y while B's stale copy still shows node1 free
        y = root.match_allocate(Jobspec.hpc(nodes=2, sockets=4, cores=64),
                                jobid="Y")
        assert y
        a = h["A"]
        a.match_allocate(Jobspec.hpc(nodes=1, sockets=2, cores=32), "Z")
        res = a.match_grow(Jobspec.hpc(nodes=1, sockets=2, cores=32), "Z")
        assert res and root.timings[-1].via_sibling == "B"
        stolen = [p for p in res.paths() if p in root.graph]
        # Y's binding survives next to Z's
        assert all(root.graph.vertex(p).allocations.get("Y")
                   for p in stolen)
        root.release("Y")
        assert all(not root.graph.vertex(p).allocations.get("Y")
                   for p in stolen if p in root.graph)
        assert root.graph.validate_tree()
    finally:
        h.close()


def test_delegation_marker_displaced_on_reclaim():
    """The normal case: vertices marked 'delegated*' at the parent are
    rebound cleanly to the requesting job (marker dropped), and return
    to the parent's free pool when that job releases."""
    root_g = build_cluster(nodes=2)
    a_g = root_g.extract([p for p in root_g.paths() if "node0" in p])
    b_g = root_g.extract([p for p in root_g.paths() if "node1" in p])
    h = build_tree(TreeSpec(root_g, name="root",
                            children=[TreeSpec(a_g, name="A"),
                                      TreeSpec(b_g, name="B")]))
    try:
        root = h["root"]
        root.graph.set_allocated(
            [p for p in root.graph.paths() if "/node" in p],
            "delegated-to-children")
        a = h["A"]
        a.match_allocate(Jobspec.hpc(nodes=1, sockets=2, cores=32), "j")
        res = a.match_grow(Jobspec.hpc(nodes=1, sockets=2, cores=32), "j")
        assert res
        donated = [p for p in res.paths() if p in root.graph]
        assert all(root.graph.vertex(p).allocations == {"j": 1}
                   for p in donated)
        a.release("j")     # propagates: root frees its copies too
        assert all(not root.graph.vertex(p).allocations for p in donated)
        assert root.graph.validate_tree()
    finally:
        h.close()


def test_aliased_parent_grow_fails_cleanly():
    """If the parent's matched subgraph fully aliases vertices the
    child already holds (namespace collision, no delegation marking),
    the grow reports failure and the parent's allocation is rolled
    back — no phantom success, no stranded capacity."""
    from repro.core import build_chain
    # both levels use the default node namespace: full alias
    h = build_chain([build_cluster(nodes=1), build_cluster(nodes=1)])
    try:
        top, leaf = h.instances
        leaf.match_allocate(Jobspec.hpc(nodes=1, sockets=2, cores=32),
                            jobid="j")
        res = leaf.match_grow(Jobspec.hpc(nodes=1, sockets=2, cores=32),
                              "j")
        assert not res
        # rollback: nothing left allocated to j at the parent
        alloc = top.allocations.get("j")
        assert alloc is None or alloc.paths == []
        assert all("j" not in top.graph.vertex(p).allocations
                   for p in top.graph.paths())
        assert top.graph.validate_tree()
    finally:
        h.close()


def test_partially_aliased_parent_grow_fails_cleanly():
    """Partial namespace collision: the parent matches 2 nodes, one of
    which the child already holds.  The grow must fail and roll back —
    booking half a grow would double-use the aliased node and strand
    the parent's allocation for it."""
    from repro.core import build_chain
    h = build_chain([build_cluster(nodes=2), build_cluster(nodes=1)])
    try:
        top, leaf = h.instances
        leaf.match_allocate(Jobspec.hpc(nodes=1, sockets=2, cores=32),
                            jobid="j")
        n_before = leaf.graph.num_vertices
        res = leaf.match_grow(Jobspec.hpc(nodes=2, sockets=4, cores=64),
                              "j")
        assert not res
        # rollback on both sides: leaf unchanged, top fully freed
        assert leaf.graph.num_vertices == n_before
        assert all("j" not in top.graph.vertex(p).allocations
                   for p in top.graph.paths())
        assert leaf.graph.validate_tree() and top.graph.validate_tree()
    finally:
        h.close()
