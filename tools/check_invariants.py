#!/usr/bin/env python
"""CLI for the concurrency lint (rules R1-R5, see docs/CONCURRENCY.md).

Usage::

    python tools/check_invariants.py                 # lint core + runtime
    python tools/check_invariants.py src/repro/core  # explicit paths
    python tools/check_invariants.py --json          # machine-readable
    python tools/check_invariants.py --list-rules

Exit status 0 when clean, 1 when any finding survives its pragma check.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))

from repro.analysis import lint  # noqa: E402

DEFAULT_PATHS = [
    os.path.join(_ROOT, "src", "repro", "core"),
    os.path.join(_ROOT, "src", "repro", "runtime"),
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*", default=None,
                    help="files or directories (default: core + runtime)")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as a JSON array")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule table and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule, desc in sorted(lint.RULES.items()):
            print(f"{rule}  {desc}")
        return 0

    paths = args.paths or DEFAULT_PATHS
    findings = lint.lint_paths(paths)
    if args.json:
        print(json.dumps([{
            "path": os.path.relpath(f.path, _ROOT)
            if f.path.startswith(_ROOT) else f.path,
            "line": f.line, "rule": f.rule, "message": f.message,
        } for f in findings], indent=2))
    else:
        for f in findings:
            path = os.path.relpath(f.path, _ROOT) \
                if f.path.startswith(_ROOT) else f.path
            print(f"{path}:{f.line}: {f.rule} {f.message}")
        if findings:
            print(f"\n{len(findings)} finding(s). See docs/CONCURRENCY.md "
                  f"for the invariants and the pragma escape hatch.")
        else:
            print("concurrency invariants: clean")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
